"""Pipeline-parallel ``pipe`` backend: 1F1B schedule, compressed chunked-
int8 p2p wire, and executable/sim parity.

Key claims:

  * ``instructions_1f1b`` / ``stage_partition`` / ``PipelineStagePolicy``
    reproduce the textbook 1F1B shape: uniform zero-comm makespan is
    exactly ``(M + S - 1) * (f + b)`` and S=1 degenerates to the serial
    sum;
  * the executable '1f1b' gradient schedule computes the SAME gradients
    as the 'minibatch' schedule (the in-flight window only reorders
    work), for any stage count and the interleaved variant;
  * with compression OFF the pipe transports are bit-exact equal to the
    hier transports they compose (the fp32 fallback contract), and a
    pipe training step matches the flat collective baseline to fp
    reordering;
  * the chunked-int8 wire: per-element error ≤ absmax(chunk)/254 (the
    documented bound), zeros round-trip exactly, the local shard lands
    exactly, and the Pallas q8 kernels match the jnp oracles;
  * the quantized loss trajectory stays within the documented bound of
    fp32 (|Δloss| < 1e-2 on the reduced config);
  * ``scheme='pipe'`` reads off the shared timeline engine with
    lockstep-shaped blocks, and int8 strictly shrinks both the modeled
    per-layer wire time and the end-to-end makespan whenever comm is
    exposed.
"""
import math
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.balance import STRATEGIES
from repro.configs import get_reduced
from repro.core import backend as B
from repro.core import odc
from repro.core.gspmd import GSPMDConfig, ShardingRules, make_train_step
from repro.data import sample_lengths
from repro.kernels import ops
from repro.launch.mesh import make_hier_mesh, make_host_mesh, make_pipe_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.sim import (
    CommModel,
    PIPE_1F1B,
    SimConfig,
    get_policy,
    instructions_1f1b,
    simulate_minibatch,
    stage_partition,
)

KEY = jax.random.PRNGKey(0)


def _shard_run(fn, mesh, in_specs, out_specs):
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False,
                            axis_names=set(mesh.axis_names))


# ===========================================================================
# 1F1B schedule primitives
# ===========================================================================
def test_stage_partition():
    assert stage_partition(24, 5) == [5, 5, 5, 5, 4]
    assert stage_partition(8, 2) == [4, 4]
    assert stage_partition(3, 5) == [1, 1, 1, 0, 0]
    assert stage_partition(0, 3) == [0, 0, 0]
    with pytest.raises(ValueError):
        stage_partition(4, 0)
    with pytest.raises(ValueError):
        stage_partition(-1, 2)


@pytest.mark.parametrize("S,M", [(1, 4), (2, 2), (3, 4), (4, 3), (4, 1)])
def test_instructions_1f1b_structure(S, M):
    for s in range(S):
        order = instructions_1f1b(M, S, stage=s)
        fwd = [j for op, j in order if op == "F"]
        bwd = [j for op, j in order if op == "B"]
        assert fwd == list(range(M)) and bwd == list(range(M))
        # every backward is preceded by its own forward
        seen = set()
        for op, j in order:
            if op == "F":
                seen.add(j)
            else:
                assert j in seen
        # warmup depth: S-1-s forwards before the first backward
        # (capped at M when the pipeline never fills)
        w = min(S - 1 - s, M)
        head = [op for op, _ in order[:w]]
        assert head == ["F"] * w
        if M > w:
            assert order[w][0] == "F" and order[w + 1][0] == "B"


def test_instructions_1f1b_interleave_halves_warmup():
    plain = instructions_1f1b(6, 4, stage=0)
    inter = instructions_1f1b(6, 4, stage=0, interleave=True)
    depth = lambda o: next(i for i, (op, _) in enumerate(o) if op == "B")
    assert depth(plain) == 3 + 1  # w forwards, first B at index w...
    assert depth(inter) < depth(plain)
    with pytest.raises(ValueError):
        instructions_1f1b(4, 2, stage=2)
    with pytest.raises(ValueError):
        instructions_1f1b(4, 0)


def test_1f1b_policy_registered():
    assert get_policy("1f1b") is PIPE_1F1B
    assert B.PIPE.policy is PIPE_1F1B
    assert B.PIPE_INT8.policy is PIPE_1F1B


def test_1f1b_uniform_makespan_analytic():
    """Uniform microbatches, zero comm: makespan = (M + S - 1)(f + b)."""
    t, L = 3.0, 8
    for S, per_dev in ((2, 2), (4, 1), (4, 3)):
        times = [[t] * per_dev for _ in range(S)]
        M = S * per_dev
        mk, blocks = PIPE_1F1B.step_blocks(times, [0.0] * S, L)
        per_mb = t / S  # f + b of one stage's slice (f = 1/3, b = 2/3)
        assert mk == pytest.approx((M + S - 1) * per_mb)
        assert len(blocks) == S
        for total, segs in blocks:  # lockstep-shaped: all lanes span mk
            assert total == pytest.approx(mk)


def test_1f1b_single_stage_is_serial():
    mk, blocks = PIPE_1F1B.step_blocks([[2.0, 4.0]], [0.0], 4)
    assert mk == pytest.approx(6.0)  # no pipeline: plain serial sum
    assert all(kind != "barrier" for kind, _, _ in blocks[0][1])


# ===========================================================================
# simulator integration
# ===========================================================================
def _plan(world=8, n=64, seed=0):
    lens = sample_lengths("longalign", n, seed=seed)
    return STRATEGIES["lb_mini"](lens, world, 65_536), lens


def test_sim_pipe_scheme_lockstep_shaped():
    plan, lens = _plan()
    r = simulate_minibatch(plan, lens, scheme="pipe", cfg=SimConfig())
    assert r.makespan > 0
    # the 1F1B drain barrier squares every lane off at the makespan
    assert max(r.device_finish) == pytest.approx(min(r.device_finish))
    assert max(r.device_finish) == pytest.approx(r.makespan)


def test_sim_pipe_int8_strictly_faster_when_comm_exposed():
    plan, lens = _plan()
    for overlap in (0.0, 0.5):
        cfg = SimConfig(overlap=overlap)
        fp = simulate_minibatch(plan, lens, scheme="pipe", cfg=cfg)
        q8 = simulate_minibatch(plan, lens, scheme="pipe-int8", cfg=cfg)
        assert q8.makespan < fp.makespan, overlap
    # fully-hidden comm: compression cannot help, the schemes tie
    cfg = SimConfig(overlap=1.0)
    fp = simulate_minibatch(plan, lens, scheme="pipe", cfg=cfg)
    q8 = simulate_minibatch(plan, lens, scheme="pipe-int8", cfg=cfg)
    assert q8.makespan == fp.makespan


def test_layer_comm_time_int8_strictly_smaller():
    cm = CommModel()
    for d in (2, 4, 8, 64):
        fp = B.PIPE.layer_comm_time(cm, d)
        q8 = B.PIPE_INT8.layer_comm_time(cm, d)
        assert 0.0 < q8 < fp, d
    assert B.PIPE.layer_comm_time(cm, 1) == 0.0
    assert B.PIPE_INT8.layer_comm_time(cm, 1) == 0.0


def test_weight_push_time_int8_wins_multi_node():
    cm = CommModel()
    assert B.PIPE.weight_push_time(cm, 16, 0) == 0.0
    g = cm.devices_per_node
    # single node: no inter wire, nothing to compress
    assert (B.PIPE_INT8.weight_push_time(cm, g, 24)
            == B.PIPE.weight_push_time(cm, g, 24))
    for d in (2 * g, 8 * g):
        fp = B.PIPE.weight_push_time(cm, d, 24)
        q8 = B.PIPE_INT8.weight_push_time(cm, d, 24)
        assert 0.0 < q8 < fp, d


# ===========================================================================
# chunked-int8 wire: error bound + transports + kernels
# ===========================================================================
def test_quantization_error_bound():
    """Per element: |x - dequant(quantize(x))| <= absmax(chunk) / 254."""
    rng = np.random.default_rng(0)
    for shape in ((7,), (3, 97), (2, 256), (5, 4, 33)):
        x = jnp.asarray((rng.normal(size=shape) * 10).astype(np.float32))
        q, s = odc.quantize_chunked(x)
        y = odc.dequantize_chunked(q, s, x.shape)
        flat = x.reshape(-1)
        pad = (-flat.size) % odc.INT8_CHUNK
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, odc.INT8_CHUNK)
        bound = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 254.0
        err = jnp.abs(jnp.pad((y - x).reshape(-1), (0, pad))
                      ).reshape(-1, odc.INT8_CHUNK)
        assert bool((err <= bound + 1e-7).all()), shape


def test_quantization_zeros_round_trip_exactly():
    z = jnp.zeros((300,), jnp.float32)
    q, s = odc.quantize_chunked(z)
    assert bool((s == 1.0).all())
    assert bool((odc.dequantize_chunked(q, s, z.shape) == z).all())


def test_codec_kernels_bit_exact_vs_oracle():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 97)).astype(np.float32))
    q, s = ops.quantize_int8(x)
    qr, sr = odc.quantize_chunked(x)
    assert bool((q == qr).all()) and bool((s == sr).all())
    y = ops.dequantize_int8(q, s, x.shape)
    yr = odc.dequantize_chunked(qr, sr, x.shape)
    assert bool((y == yr).all())


def test_ring_gather_q8_own_shard_exact_and_bounded():
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    n = len(jax.devices())
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.normal(size=(2 * n, 5)).astype(np.float32))

    def f(x):
        full = odc.ring_gather_q8(x, "data")
        me = jax.lax.axis_index("data")
        own = jax.lax.dynamic_slice_in_dim(full, me * x.shape[0],
                                           x.shape[0], 0)
        return full, (own == x).all()[None]

    full, own_ok = _shard_run(f, mesh, (P("data"),), (P("data"), P("data")))(xs)
    assert bool(own_ok.all())  # the local shard is never quantized
    ref = _shard_run(lambda x: odc.ring_gather(x, "data"), mesh,
                     (P("data"),), P("data"))(xs)
    bound = float(jnp.max(jnp.abs(xs))) / 254.0
    assert float(jnp.max(jnp.abs(full - ref))) <= bound + 1e-7


def test_ring_scatter_q8_error_compounds_at_most_n_hops():
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    n = len(jax.devices())
    rng = np.random.default_rng(3)
    ys = jnp.asarray(rng.normal(size=(4 * n, 6)).astype(np.float32))
    q8 = _shard_run(lambda y: odc.ring_scatter_accumulate_q8(y, "data"),
                    mesh, (P(None),), P("data"))(ys)
    fp = _shard_run(lambda y: odc.ring_scatter_accumulate(y, "data"),
                    mesh, (P(None),), P("data"))(ys)
    # each of the n-1 hops requantizes a partial sum whose magnitude is at
    # most the sum of |y| over devices — a loose but airtight bound
    per_hop = float(jnp.max(jnp.abs(ys))) * n / 254.0
    assert float(jnp.max(jnp.abs(q8 - fp))) <= (n - 1) * per_hop


def test_q8_kernels_match_jnp_oracles():
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    rng = np.random.default_rng(4)
    xs = jnp.asarray(rng.normal(size=(16, 5, 7)).astype(np.float32))
    k = _shard_run(lambda t: ops.odc_gather_q8(t, "data"), mesh,
                   (P("data"),), P("data"))(xs)
    r = _shard_run(lambda t: odc.ring_gather_q8(t, "data"), mesh,
                   (P("data"),), P("data"))(xs)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-6)

    ys = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    k2 = _shard_run(lambda t: ops.odc_scatter_accumulate_q8(t, "data"),
                    mesh, (P(None),), P("data"))(ys)
    r2 = _shard_run(lambda t: odc.ring_scatter_accumulate_q8(t, "data"),
                    mesh, (P(None),), P("data"))(ys)
    assert bool((k2 == r2).all())  # same hop order, same adds: bit-exact


def test_backend_kernel_hooks_route_by_compression():
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    kq = _shard_run(lambda t: B.PIPE_INT8.kernel_gather(t, "data"), mesh,
                    (P("data"),), P("data"))(xs)
    rq = _shard_run(lambda t: odc.ring_gather_q8(t, "data"), mesh,
                    (P("data"),), P("data"))(xs)
    np.testing.assert_allclose(np.asarray(kq), np.asarray(rq), atol=1e-6)
    kf = _shard_run(lambda t: B.PIPE.kernel_gather(t, "data"), mesh,
                    (P("data"),), P("data"))(xs)
    rf = _shard_run(lambda t: odc.ring_gather(t, "data"), mesh,
                    (P("data"),), P("data"))(xs)
    assert bool((kf == rf).all())


def test_pipe_transports_bit_exact_vs_hier_when_uncompressed():
    """Compression off ⇒ the pipe gather/scatter are byte-for-byte the
    hier two-tier transports (the fp32 fallback contract)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 host devices")
    devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
    mesh = Mesh(devs, ("pipe", "data"))
    xs = jnp.arange(16.0).reshape(8, 2) * 1.3

    def g(x):
        a = B.PIPE.gather(x, ("pipe", "data"))
        b = B.HIER.gather(x, ("pipe", "data"))
        return a, b

    a, b = _shard_run(g, mesh, (P(("pipe", "data")),), (P(), P()))(xs)
    assert bool((a == b).all())

    ys = jnp.arange(32.0).reshape(16, 2)

    def s(y):
        a = B.PIPE.scatter_accumulate(y, ("pipe", "data"))
        b = B.HIER.scatter_accumulate(y, ("pipe", "data"))
        return a, b

    a, b = _shard_run(s, mesh, (P(None),),
                      (P(("pipe", "data")), P(("pipe", "data"))))(ys)
    assert bool((a == b).all())


# ===========================================================================
# executable 1F1B gradient schedule
# ===========================================================================
def _toy_loss(p, mb, px, prefetch=None):
    v = jnp.sum((p["w"] * mb["x"]) ** 2)
    return v, jnp.float32(mb["x"].size)


def test_build_schedule_grad_1f1b_validation():
    with pytest.raises(ValueError, match="gather_all"):
        B.build_schedule_grad("1f1b", loss_sum=_toy_loss)
    with pytest.raises(ValueError, match="pipe_stages"):
        B.build_schedule_grad("1f1b", loss_sum=_toy_loss,
                              gather_all=lambda p: p, pipe_stages=0)


@pytest.mark.parametrize("stages,interleave",
                         [(1, False), (2, False), (3, False), (8, False),
                          (2, True), (4, True)])
def test_1f1b_grads_match_minibatch_schedule(stages, interleave):
    """The in-flight 1F1B window only reorders the per-microbatch VJPs —
    loss, token count, and gradients must match the minibatch schedule."""
    params = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    mbs = {"x": jnp.asarray(np.random.default_rng(6).normal(
        size=(4, 3)).astype(np.float32))}
    ref = B.build_schedule_grad("minibatch", loss_sum=_toy_loss,
                                gather_all=lambda p: p)(params, mbs)
    got = B.build_schedule_grad("1f1b", loss_sum=_toy_loss,
                                gather_all=lambda p: p,
                                pipe_stages=stages,
                                pipe_interleave=interleave)(params, mbs)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_1f1b_zero_microbatches_yields_zero_grads():
    params = {"w": jnp.asarray([1.0, 2.0])}
    mbs = {"x": jnp.zeros((0, 2), jnp.float32)}
    lsum, tok, grads = B.build_schedule_grad(
        "1f1b", loss_sum=_toy_loss, gather_all=lambda p: p,
        pipe_stages=2)(params, mbs)
    assert float(lsum) == 0.0 and float(tok) == 0.0
    assert bool((grads["w"] == 0.0).all())


# ===========================================================================
# end-to-end GSPMD engine
# ===========================================================================
def _batch(cfg, M=2, Bm=8, S=32):
    kb = jax.random.PRNGKey(1)
    return {
        "tokens": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "positions": jnp.tile(jnp.arange(S)[None, None], (M, Bm, 1)),
        "segment_ids": jnp.zeros((M, Bm, S), jnp.int32),
        "targets": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((M, Bm, S), jnp.float32),
    }


def _run_gcfg(cfg, mesh, params, batch, gcfg):
    step = make_train_step(cfg, mesh, gcfg, AdamWConfig(lr=1e-2))
    with mesh:
        newp, _, metrics = jax.jit(step)(params, adamw_init(params), batch)
    return newp, metrics


def _max_param_delta(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_pipe_requires_two_axes():
    cfg = get_reduced("qwen-1.5b")
    mesh = make_host_mesh(data=8, model=1)
    with pytest.raises(ValueError, match="2D mesh"):
        make_train_step(cfg, mesh,
                        GSPMDConfig(rules=ShardingRules(), comm="pipe"))


def test_pipe_matches_collective_and_int8_within_bound():
    """fp32 pipe matches the flat collective baseline to fp reordering;
    pipe-int8's loss stays within the DOCUMENTED quantization bound
    (|Δloss| < 1e-2 on the reduced config); the interleaved variant sums
    the same terms."""
    cfg = get_reduced("qwen-1.5b")
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)

    base_p, base_m = _run_gcfg(
        cfg, make_host_mesh(data=8, model=1), params, batch,
        GSPMDConfig(rules=ShardingRules(), schedule="minibatch",
                    comm="collective", block_kv=64))

    mesh = make_pipe_mesh(stages=2, model=1)
    rules = ShardingRules(data=("pipe", "data"))
    pipe_p, pipe_m = _run_gcfg(
        cfg, mesh, params, batch,
        GSPMDConfig(rules=rules, comm="pipe", block_kv=64))
    assert abs(float(pipe_m["loss"]) - float(base_m["loss"])) < 1e-5
    assert _max_param_delta(pipe_p, base_p) < 1e-3

    q8_p, q8_m = _run_gcfg(
        cfg, mesh, params, batch,
        GSPMDConfig(rules=rules, comm="pipe-int8", block_kv=64))
    assert abs(float(q8_m["loss"]) - float(pipe_m["loss"])) < 1e-2

    il_p, il_m = _run_gcfg(
        cfg, mesh, params, batch,
        GSPMDConfig(rules=rules, comm="pipe", pipe_interleave=True,
                    block_kv=64))
    assert abs(float(il_m["loss"]) - float(pipe_m["loss"])) < 1e-6


@pytest.mark.slow
def test_pipe_int8_loss_trajectory_within_bound():
    """Two training steps with the quantized wire track fp32 within the
    documented bound at every step."""
    cfg = get_reduced("qwen-1.5b")
    params = T.init_params(cfg, KEY)
    mesh = make_pipe_mesh(stages=2, model=1)
    rules = ShardingRules(data=("pipe", "data"))

    def run(comm):
        gcfg = GSPMDConfig(rules=rules, comm=comm, block_kv=64)
        step = jax.jit(make_train_step(cfg, mesh, gcfg, AdamWConfig(lr=1e-2)))
        p, opt = params, adamw_init(params)
        losses = []
        for i in range(2):
            with mesh:
                p, opt, m = step(p, opt, _batch(cfg))
            losses.append(float(m["loss"]))
        return losses

    fp = run("pipe")
    q8 = run("pipe-int8")
    assert all(abs(a - b) < 1e-2 for a, b in zip(fp, q8)), (fp, q8)
