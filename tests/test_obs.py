"""Observability layer (repro.obs): registry invariants, comm-byte
accounting through the backend seam, sim-vs-real schema identity, and
the divergence report.

Key claims:
  * the metrics registry's instruments hold their contracts — counters
    are monotone, the log2 histogram's buckets cover every message size
    with an explicit overflow, labels round-trip through the JSONL
    snapshot stream;
  * a REAL run (executable ``param_gather`` under shard_map) and a SIM
    run (``simulate_minibatch``'s cost hooks) of the same config emit
    metrics with IDENTICAL counter-name sets — the schema contract the
    divergence tooling aligns on;
  * comm-byte accounting is conservative: flat ODC's logical gather
    bytes equal ``(world - 1) x shard_bytes`` exactly, and pipe-int8's
    inter-tier wire ratio is the measured ``int8_wire_factor``;
  * recording NEVER perturbs simulated arithmetic (makespans equal with
    and without a registry — the BENCH byte-identity guarantee);
  * a seeded sim-vs-sim trace pair diverges by exactly zero (all
    calibration scalars 1.0 where evidence exists).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.balance import STRATEGIES
from repro.core import backend as B
from repro.data import sample_lengths
from repro.obs import divergence as obs_div
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.sim import CommModel, SimConfig, simulate_minibatch
from repro.sim.trace import chrome_trace

WORLD = 8


# ===========================================================================
# registry invariants
# ===========================================================================
def test_counter_monotone():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("comm.bytes_wire", backend="odc")
    c.inc(5.0)
    c.inc(0.0)
    assert c.value == 5.0
    with pytest.raises(ValueError, match="monotone"):
        c.inc(-1.0)
    with pytest.raises(ValueError, match="monotone"):
        c.inc_per_step(-1.0)


def test_histogram_bucket_cover_and_overflow():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("comm.message_bytes")
    # one observation into every bucket, plus one beyond the last bound
    for ub in obs_metrics.LOG2_BUCKETS:
        h.observe(ub)
    h.observe(2.0 ** 60)
    assert h.count == len(obs_metrics.LOG2_BUCKETS) + 1
    assert sum(h.counts) == h.count  # buckets + overflow partition all
    assert h.counts[-1] == 1  # the 2^60 observation overflowed
    row = h.to_row()
    assert row["buckets"]["overflow"] == 1
    assert row["buckets"]["1"] == 1  # 2^0 landed in the first bucket
    # quantiles are bucket upper bounds, monotone in q
    assert h.quantile(0.5) <= h.quantile(0.95)


def test_labels_round_trip_through_jsonl(tmp_path):
    path = str(tmp_path / "m.jsonl")
    reg = obs_metrics.MetricsRegistry(meta={"driver": "test"})
    reg.attach_jsonl(path)
    reg.counter("comm.messages", backend="odc", op="gather",
                tier="flat").inc(3.0)
    reg.gauge("train.loss").set(1.5)
    reg.histogram("comm.message_bytes", backend="odc", op="gather",
                  tier="flat").observe(1024.0, 3.0)
    reg.step(0)
    reg.close()
    meta, rows = obs_metrics.read_jsonl(path)
    assert meta == {"driver": "test"}
    assert len(rows) == 1
    names = obs_metrics.metric_names(rows)
    assert "comm.messages{backend=odc,op=gather,tier=flat}" in names
    assert "train.loss" in names
    got = {m["name"]: m for m in rows[0]["metrics"]}
    assert got["comm.messages"]["labels"] == {
        "backend": "odc", "op": "gather", "tier": "flat"}
    assert got["comm.message_bytes"]["buckets"] == {"1024": 3.0}


def test_per_step_ledger_and_program_scopes():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("comm.bytes_wire")
    with obs_metrics.recording(reg):
        with reg.program("step"):
            c.inc_per_step(10.0)
    reg.step(0)
    reg.step(1)
    assert c.value == 20.0  # ledger commits on every step
    # a retrace REPLACES the program's group (the old program is dead)
    with reg.program("step"):
        c.inc_per_step(1.0)
    reg.step(2)
    assert c.value == 21.0
    # trace_scale multiplies (scan bodies traced once, run L times)
    with reg.program("step"):
        with obs_metrics.trace_scale(4):
            c.inc_per_step(1.0)
    reg.step(3)
    assert c.value == 25.0


# ===========================================================================
# the comm-byte accounting seam
# ===========================================================================
def _shard_run(fn, mesh, in_specs, out_specs):
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False,
                            axis_names=set(mesh.axis_names))


def _real_counter_rows(backend_name, mesh, axis, spec, x, tmp_path, tag):
    """Run one real fwd+bwd param_gather under a recording registry and
    return the JSONL snapshot rows."""
    path = str(tmp_path / f"real_{tag}.jsonl")
    reg = obs_metrics.MetricsRegistry(meta={"source": "real"})
    reg.attach_jsonl(path)
    with obs_metrics.recording(reg):
        def f(xs):
            g = B.get_backend(backend_name).param_gather(axis)
            return jax.grad(lambda s: (g(s) ** 2).sum() / 2)(xs)
        with reg.program("step"):
            _shard_run(f, mesh, (spec,), spec)(x)
        reg.step(0)
    reg.close()
    return obs_metrics.read_jsonl(path)[1]


def _sim_counter_rows(backend_name, cfg, tmp_path, tag):
    path = str(tmp_path / f"sim_{tag}.jsonl")
    reg = obs_metrics.MetricsRegistry(meta={"source": "sim"})
    reg.attach_jsonl(path)
    lens = sample_lengths("longalign", WORLD * 2, 0).tolist()
    plan = STRATEGIES["lb_mini"](lens, WORLD, 65_536)
    with obs_metrics.recording(reg):
        simulate_minibatch(plan, lens, scheme=backend_name, cfg=cfg)
        reg.step(0)
    reg.close()
    return obs_metrics.read_jsonl(path)[1]


@pytest.mark.parametrize("name", ["odc", "collective", "hier"])
def test_sim_and_real_counter_names_identical(name, tmp_path):
    """The acceptance contract: a sim run and a real run of one config
    emit metrics JSONL with IDENTICAL comm counter-name sets."""
    if name == "hier":
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("node", "device"))
        axis = ("node", "device")
        spec = P(("node", "device"))
        x = jnp.arange(64.0).reshape(32, 2)
        cfg = SimConfig(comm=CommModel(devices_per_node=4))
    else:
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        axis = "data"
        spec = P("data")
        x = jnp.arange(32.0)
        cfg = SimConfig(comm=CommModel(devices_per_node=WORLD))
    real = _real_counter_rows(name, mesh, axis, spec, x, tmp_path, name)
    sim = _sim_counter_rows(name, cfg, tmp_path, name)
    real_names = obs_metrics.metric_names(real, kind="counter",
                                          prefix="comm.")
    sim_names = obs_metrics.metric_names(sim, kind="counter",
                                         prefix="comm.")
    assert real_names == sim_names
    assert real_names  # non-empty: the seam actually recorded
    # histograms carry the same identity too
    assert (obs_metrics.metric_names(real, kind="histogram")
            == obs_metrics.metric_names(sim, kind="histogram"))


def test_flat_odc_bytes_conservation():
    """Logical gather bytes == (world - 1) x shard_bytes, exactly: the
    ring moves every other device's shard to me, once."""
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    x = jnp.arange(64, dtype=jnp.float32)
    shard_bytes = (x.size // WORLD) * x.dtype.itemsize  # 32 bytes/device
    reg = obs_metrics.MetricsRegistry()
    with obs_metrics.recording(reg):
        def f(xs):
            return B.ODC.param_gather("data")(xs)
        with reg.program("step"):
            _shard_run(f, mesh, (P("data"),), P())(x)
        reg.step(0)
    assert reg.total("comm.bytes_logical", op="gather") == \
        (WORLD - 1) * shard_bytes
    assert reg.total("comm.messages", op="gather") == WORLD - 1
    # wire == logical on the uncompressed flat ring
    assert reg.total("comm.bytes_wire", op="gather") == \
        reg.total("comm.bytes_logical", op="gather")


def test_pipe_int8_inter_wire_ratio_is_measured_fact():
    """pipe-int8's 0.254x wire ratio is a fact the counters measure:
    inter-tier wire/logical == int8_wire_factor, intra unchanged."""
    shard = 1024.0 * 1024.0
    vols = {t: (logical, wire) for t, _, logical, wire
            in B.PIPE_INT8.comm_volume("gather", shard, 8, 4)}
    assert vols["inter"][1] / vols["inter"][0] == \
        pytest.approx(B.PIPE_INT8.int8_wire_factor)
    assert B.PIPE_INT8.int8_wire_factor == pytest.approx(0.254, abs=1e-3)
    assert vols["intra"][1] == vols["intra"][0]
    # and hier's two-tier split partitions the flat volume's shard sets
    g, n = 4, 2
    intra_l = vols["intra"][0]
    inter_l = vols["inter"][0]
    assert intra_l == (g - 1) * shard
    assert inter_l == (n - 1) * g * shard


def test_recording_does_not_perturb_sim_arithmetic():
    """The BENCH byte-identity guarantee: a simulated run computes the
    exact same floats with and without a registry recording."""
    lens = sample_lengths("longalign", WORLD * 4, 0).tolist()
    plan = STRATEGIES["lb_mini"](lens, WORLD, 65_536)
    base = {}
    for scheme in ("odc", "collective", "hier", "odc-overlap"):
        base[scheme] = simulate_minibatch(plan, lens, scheme=scheme)
    reg = obs_metrics.MetricsRegistry()
    with obs_metrics.recording(reg):
        for scheme, want in base.items():
            got = simulate_minibatch(plan, lens, scheme=scheme)
            assert got.makespan == want.makespan, scheme
            assert got.device_busy == want.device_busy, scheme
            assert got.bubble_rate == want.bubble_rate, scheme
    assert reg.total("comm.bytes_wire") > 0  # it DID record


# ===========================================================================
# counter tracks in the chrome trace
# ===========================================================================
def test_timeline_counter_track_serializes():
    lens = sample_lengths("longalign", WORLD * 2, 0).tolist()
    plan = STRATEGIES["lb_mini"](lens, WORLD, 65_536)
    r = simulate_minibatch(plan, lens, scheme="odc")
    trace = chrome_trace(r.timeline)
    tracks = [ev for ev in trace["traceEvents"] if ev.get("ph") == "C"]
    assert tracks, "sim timelines carry a cumulative wire-bytes track"
    assert tracks[0]["name"] == "comm wire bytes"
    assert tracks[0]["args"]["value"] > 0


# ===========================================================================
# divergence report
# ===========================================================================
def _seeded_sim_trace(seed):
    lens = sample_lengths("longalign", WORLD * 2, seed).tolist()
    plan = STRATEGIES["lb_mini"](lens, WORLD, 65_536)
    r = simulate_minibatch(plan, lens, scheme="odc",
                           cfg=SimConfig(overlap=0.0))
    return chrome_trace(r.timeline)


def test_divergence_zero_for_identical_seeded_pair():
    a, b = _seeded_sim_trace(0), _seeded_sim_trace(0)
    rep = obs_div.compare_traces(a, b)
    assert rep.makespan_error == 0.0
    assert rep.idle_l1 == 0.0
    assert rep.real_only_lanes == [] and rep.sim_only_lanes == []
    for kind, (r, s, d) in rep.kind_totals.items():
        assert d == 0.0, kind
    for lane, kt in rep.per_lane.items():
        for kind, (r, s, d) in kt.items():
            assert d == 0.0, (lane, kind)
    for hook, scalar in rep.calibration.items():
        assert scalar is None or scalar == 1.0, hook
    # at least ONE hook has evidence (exposed comm at overlap=0.0)
    assert any(s == 1.0 for s in rep.calibration.values())
    text = rep.render()
    assert "makespan error: +0.000%" in text


def test_divergence_sees_a_real_gap():
    a, b = _seeded_sim_trace(0), _seeded_sim_trace(3)
    rep = obs_div.compare_traces(a, b)
    assert rep.real_makespan != rep.sim_makespan
    assert rep.calibration["time_per_cost"] not in (None, 1.0)


# ===========================================================================
# report CLI (sim-vs-sim pair, end to end)
# ===========================================================================
def test_report_cli_simulate_and_render(tmp_path, capsys):
    from repro.launch import report as report_cli
    m1, t1 = str(tmp_path / "a.jsonl"), str(tmp_path / "a.json")
    m2, t2 = str(tmp_path / "b.jsonl"), str(tmp_path / "b.json")
    args = ["--simulate", "--comm", "odc", "--world", "8", "--steps", "2"]
    assert report_cli.main(args + ["--metrics", m1, "--trace", t1]) == 0
    assert report_cli.main(args + ["--metrics", m2, "--trace", t2]) == 0
    out = str(tmp_path / "report.md")
    assert report_cli.main(["--metrics", m1, "--sim-metrics", m2,
                            "--trace", t1, "--sim-trace", t2,
                            "-o", out]) == 0
    capsys.readouterr()
    with open(out) as f:
        text = f.read()
    assert "counter name sets: **IDENTICAL**" in text
    assert "Cost-hook calibration" in text
    assert "| `time_per_cost` | 1.0000 |" in text  # same seeds: zero gap
    assert "Comm bytes by backend / op / tier" in text


# ===========================================================================
# the log helper
# ===========================================================================
def test_runlog_quiet_and_thinning(capsys):
    out = obs_log.RunLog("train")
    out.info("config line")
    out.step(0, "s0")
    out.always("done")
    got = capsys.readouterr().out
    assert got == "[train] config line\n[train] s0\n[train] done\n"

    quiet = obs_log.RunLog("train", quiet=True)
    quiet.info("config line")
    quiet.step(0, "s0")
    quiet.always("done")
    assert capsys.readouterr().out == "[train] done\n"

    thin = obs_log.RunLog("train", every=2)
    for i in range(4):
        thin.step(i, f"s{i}")
    assert capsys.readouterr().out == "[train] s0\n[train] s2\n"


# ===========================================================================
# golden-check helper (benchmarks/common.py)
# ===========================================================================
def test_check_golden_status_transitions(tmp_path):
    import os as _os
    import sys as _sys
    _sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
    try:
        from benchmarks.common import check_golden
    finally:
        _sys.path.pop(0)
    path = str(tmp_path / "BENCH_x.json")
    rows = [{"a": 1.0}]
    p, status = check_golden(path, "x", {"k": 1}, rows)
    assert (p, status) == (path, "created")
    _, status = check_golden(path, "x", {"k": 1}, rows)
    assert status == "byte-identical"
    _, status = check_golden(path, "x", {"k": 1}, [{"a": 2.0}])
    assert status == "changed"
