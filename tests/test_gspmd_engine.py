"""GSPMD/shard_map production-engine tests (8 host devices).

Key semantic claims tested (paper §3, Appendix F):
  * ODC (p2p comm / minibatch schedule) produces bit-comparable training
    steps to the collective FSDP baseline — the communication scheme does
    not change training semantics.
  * Dense-family distributed steps match a single-device reference.
  * The collective schedules differ exactly as designed: per-layer
    all-gather/reduce-scatter vs p2p permute chains vs once-per-minibatch.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.core.gspmd import GSPMDConfig, ShardingRules, make_train_step
from repro.core.gspmd import build_serve_artifacts, build_train_artifacts
from repro.launch import hlo as H
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)
MODES = [("layer", "collective"), ("layer", "odc"),
         ("minibatch", "collective"), ("minibatch", "odc")]


def _mesh():
    # TP + FSDP when the installed XLA supports partially-manual SPMD;
    # pure FSDP (the paper's setting) otherwise — the schedule/comm
    # semantics under test live entirely on the data axis.
    from repro import compat
    if compat.supports_partial_auto():
        return make_host_mesh(data=4, model=2)
    return make_host_mesh(data=8, model=1)


def _batch(cfg, M=2, Bm=8, S=32):
    kb = jax.random.PRNGKey(1)
    b = {
        "tokens": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "positions": jnp.tile(jnp.arange(S)[None, None], (M, Bm, 1)),
        "segment_ids": jnp.zeros((M, Bm, S), jnp.int32),
        "targets": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((M, Bm, S), jnp.float32),
    }
    if cfg.family == "audio":
        b["encoder_embeds"] = jax.random.normal(kb, (M, Bm, 16, cfg.d_model))
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        b["vision_embeds"] = jax.random.normal(
            kb, (M, Bm, cfg.frontend_tokens, cfg.d_model))
    return b


def _run_mode(cfg, mesh, params, batch, sched, comm):
    gcfg = GSPMDConfig(rules=ShardingRules(), schedule=sched, comm=comm,
                       block_kv=64)
    step = make_train_step(cfg, mesh, gcfg, AdamWConfig(lr=1e-2))
    with mesh:
        newp, _, metrics = jax.jit(step)(params, adamw_init(params), batch)
    return newp, metrics


# tier-1 keeps one dense family (gemma2); the rest run in the CI full job
@pytest.mark.parametrize("arch", [
    "gemma2-9b",
    pytest.param("mamba2-2.7b", marks=pytest.mark.slow),
    pytest.param("zamba2-1.2b", marks=pytest.mark.slow),
    pytest.param("seamless-m4t-medium", marks=pytest.mark.slow),
])
def test_dense_families_match_single_device_reference(arch):
    cfg = get_reduced(arch)
    mesh = _mesh()
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    M = batch["tokens"].shape[0]

    def ref_loss(p):
        tot, tok = jnp.float32(0), jnp.float32(0)
        for m in range(M):
            mb = jax.tree.map(lambda x: x[m], batch)
            l, met = T.loss(cfg, p, mb, reduction="sum", block_kv=64)
            tot, tok = tot + l, tok + met["tokens"]
        return tot / tok

    ref_l = ref_loss(params)
    ref_g = jax.grad(ref_loss)(params)
    ref_p, _ = adamw_update(AdamWConfig(lr=1e-2), params, ref_g,
                            adamw_init(params))
    for sched, comm in MODES:
        newp, metrics = _run_mode(cfg, mesh, params, batch, sched, comm)
        assert abs(float(metrics["loss"]) - float(ref_l)) < 1e-4, (sched, comm)
        dp = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(newp),
                                 jax.tree.leaves(ref_p)))
        assert dp < 2e-3, (sched, comm, dp)


@pytest.mark.parametrize("arch", [
    pytest.param("llama4-maverick-400b-a17b", marks=pytest.mark.slow),
    "grok-1-314b",
])
def test_odc_matches_collective_baseline_moe(arch):
    """The paper's semantic claim: ODC == collective FSDP, step for step.
    (MoE capacity dropping depends on the device-local dispatch groups, so
    the distributed runs are compared against each other, not against an
    8-way-batched single-device run.)"""
    cfg = get_reduced(arch)
    mesh = _mesh()
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    base_p, base_m = _run_mode(cfg, mesh, params, batch, "layer", "collective")
    for sched, comm in MODES[1:]:
        newp, metrics = _run_mode(cfg, mesh, params, batch, sched, comm)
        assert abs(float(metrics["loss"]) - float(base_m["loss"])) < 1e-5
        dp = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(newp),
                                 jax.tree.leaves(base_p)))
        assert dp < 1e-3, (sched, comm, dp)


def test_collective_schedule_structure():
    """Lowered HLO must show the designed communication schedules."""
    cfg = get_reduced("gemma2-9b")
    mesh = _mesh()

    def counts(sched, comm):
        gcfg = GSPMDConfig(rules=ShardingRules(), schedule=sched, comm=comm,
                           block_kv=64)
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in _batch(cfg).items()}
        jitted, args = build_train_artifacts(cfg, mesh, gcfg, batch)
        cost = H.analyze_hlo_text(jitted.lower(*args).compile().as_text())
        return cost

    lc = counts("layer", "collective")
    lo = counts("layer", "odc")
    mc = counts("minibatch", "collective")
    # baseline: all-gathers + reduce-scatters present
    assert lc.coll_count["all-gather"] > 0
    assert lc.coll_count["reduce-scatter"] > 0
    # ODC comm: p2p permutes replace the fused collectives entirely
    assert lo.coll_count["all-gather"] == 0
    assert lo.coll_count["reduce-scatter"] == 0
    assert lo.coll_count["collective-permute"] > 0
    # minibatch schedule: strictly fewer sync points than per-layer
    assert (mc.coll_count["all-gather"] + mc.coll_count["reduce-scatter"]
            < lc.coll_count["all-gather"] + lc.coll_count["reduce-scatter"])
    # identical total p2p volume claim (paper Table 2): ODC moves the same
    # order of bytes as the collective it replaces (ring AG == p2p chain).
    # HLO cost accounting counts each of the n-1 ring hops separately while
    # the fused op is counted once, so the bound is mesh-width-dependent:
    # ~1.1x at data=4, up to ~2x at data=8 (pure-FSDP fallback mesh).
    assert lo.total_coll_bytes <= 2.2 * lc.total_coll_bytes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_artifacts_lower(arch):
    cfg = get_reduced(arch)
    mesh = _mesh()
    gcfg = GSPMDConfig(rules=ShardingRules(), block_kv=64)
    for kind, B, S in [("prefill", 8, 128), ("decode", 8, 128),
                       ("decode", 1, 256)]:
        jitted, args = build_serve_artifacts(cfg, mesh, gcfg, kind=kind,
                                             batch=B, seq_len=S)
        assert jitted.lower(*args).compile() is not None


def test_multipod_flat_and_hybrid_lower():
    from repro import compat
    cfg = get_reduced("gemma2-9b")
    mesh = (make_host_mesh(data=2, model=2, pod=2)
            if compat.supports_partial_auto()
            else make_host_mesh(data=4, model=1, pod=2))
    batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in _batch(cfg).items()}
    for rules, hyb in [
        (ShardingRules(data=("pod", "data"), model="model", pod=None), False),
        (ShardingRules(data="data", model="model", pod="pod"), True),
    ]:
        gcfg = GSPMDConfig(rules=rules, schedule="minibatch", comm="odc",
                           hybrid_pod=hyb, block_kv=64)
        jitted, args = build_train_artifacts(cfg, mesh, gcfg, batch)
        assert jitted.lower(*args).compile() is not None
