"""Test-session setup.

jax locks the device count at first init, and pytest imports test modules
in file order — so the 8-host-device flag the distributed tests need must
be set before ANY module imports jax.  (This is deliberately 8, not the
dry-run's 512: only `repro.launch.dryrun` builds the production mesh, in
its own process.)

The tests are written against the current ``jax.shard_map`` API; on older
jax (0.4.x, where shard_map still lives in jax.experimental) the
``repro.compat`` wrapper is aliased in so the same test code runs
unchanged.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

if not hasattr(jax, "shard_map"):
    from repro import compat

    jax.shard_map = compat.shard_map


# ---------------------------------------------------------------------------
# fault injection: seeded straggler profiles
# ---------------------------------------------------------------------------
STRAGGLER_KINDS = ("uniform", "one_slow", "bimodal")


@pytest.fixture(scope="session")
def straggler_profiles():
    """Factory for seeded fault-injection device profiles.

    The canonical vocabulary ('uniform' | 'one_slow' | 'bimodal', plus
    'homogeneous' as the control) lives in
    ``repro.balance.cost.make_straggler_profile`` so
    ``benchmarks/straggler_sweep.py`` injects the *same* faults the tests
    assert against.  Session-scoped so hypothesis tests may use it.
    """
    from repro.balance import make_straggler_profile

    def make(kind, world=8, *, slow_factor=2.0, seed=0, jitter=0.0):
        return make_straggler_profile(kind, world, slow_factor=slow_factor,
                                      seed=seed, jitter=jitter)

    return make
