"""Test-session setup.

jax locks the device count at first init, and pytest imports test modules
in file order — so the 8-host-device flag the distributed tests need must
be set before ANY module imports jax.  (This is deliberately 8, not the
dry-run's 512: only `repro.launch.dryrun` builds the production mesh, in
its own process.)
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
