"""System-level tests: balance strategies, simulator, data pipeline,
checkpointing, HLO analyzer, end-to-end drivers."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

from repro.balance import STRATEGIES, karmarkar_karp, verl_native, verl_optimized
from repro.balance.cost import CostModel, get_compute_costs
from repro.balance.kk import imbalance, partition_sums
from repro.data import DATASETS, pack_sequences, sample_lengths
from repro.sim import SimConfig, simulate_minibatch


# ===========================================================================
# balance
# ===========================================================================
def test_kk_basic():
    parts = karmarkar_karp([1, 2, 3, 4, 5, 6, 7, 8], 2)
    assert sorted(partition_sums([1, 2, 3, 4, 5, 6, 7, 8], parts)) == [18, 18]


def test_kk_equal_size():
    parts = karmarkar_karp([5, 5, 5, 5, 1, 1, 1, 1], 4, equal_size=True)
    assert all(len(p) == 2 for p in parts)
    assert partition_sums([5, 5, 5, 5, 1, 1, 1, 1], parts) == [6, 6, 6, 6]


@pytest.mark.parametrize("strategy", list(STRATEGIES))
def test_strategies_cover_all_samples(strategy):
    lens = sample_lengths("longalign", 64, 0).tolist()
    lens = [min(l, 65_536) for l in lens]
    plan = STRATEGIES[strategy](lens, 8, 65_536)
    plan.validate(len(lens))
    # memory budget respected
    for dev in plan.assignments:
        for mb in dev:
            assert sum(lens[i] for i in mb) <= 65_536


def test_lb_mini_allows_unequal_microbatches():
    lens = sample_lengths("longalign", 64, 3).tolist()
    plan = STRATEGIES["lb_mini"](lens, 8, 65_536)
    # LB-Mini balances cost, not counts — device totals are tighter than
    # LocalSort's
    costs = get_compute_costs(lens)
    assert imbalance(costs, [[i for mb in d for i in mb]
                             for d in plan.assignments]) < \
        imbalance(costs, [[i for mb in d for i in mb]
                          for d in STRATEGIES["local_sort"](
                              lens, 8, 65_536).assignments])


def test_verl_optimized_beats_native():
    lens = sample_lengths("aime", 8 * 16, 0).tolist()
    costs = get_compute_costs(lens)

    def worst(plans):
        return max(imbalance(costs, [[i for mb in d for i in mb]
                                     for d in p.assignments]) for p in plans)

    native = verl_native(lens, 8, 16_384, minibatch_size=4)
    opt = verl_optimized(lens, 8, 16_384, minibatch_size=4)
    assert worst(opt) <= worst(native)


# ===========================================================================
# simulator (paper Eq. 1 vs ODC)
# ===========================================================================
def test_sim_odc_never_slower_and_ties_at_minibs1():
    for mb in (1, 8):
        lens = sample_lengths("longalign", 8 * mb, 1).tolist()
        lens = [min(l, 65_536) for l in lens]
        plan = STRATEGIES["lb_mini"](lens, 8, 65_536)
        t_coll = simulate_minibatch(plan, lens, scheme="collective").makespan
        t_odc = simulate_minibatch(plan, lens, scheme="odc").makespan
        assert t_odc <= t_coll * (1 + 1e-9)
        if plan.max_microbatches == 1:
            assert abs(t_odc - t_coll) < 1e-9


def test_sim_bubble_rate_bounds():
    lens = sample_lengths("swesmith", 64, 2).tolist()
    lens = [min(l, 32_768) for l in lens]
    for strat in STRATEGIES:
        plan = STRATEGIES[strat](lens, 8, 32_768)
        for scheme in ("collective", "odc"):
            r = simulate_minibatch(plan, lens, scheme=scheme)
            assert 0.0 <= r.bubble_rate < 1.0


# ===========================================================================
# data pipeline
# ===========================================================================
def test_length_distributions_shapes():
    for name, spec in DATASETS.items():
        l = sample_lengths(name, 5000, 0)
        assert l.max() <= spec.max_len and l.min() >= spec.min_len
        # deterministic per seed
        assert np.array_equal(l, sample_lengths(name, 5000, 0))
        assert not np.array_equal(l, sample_lengths(name, 5000, 1))


def test_packing_segments_and_targets():
    toks = [np.arange(1, 6, dtype=np.int32), np.arange(10, 13, dtype=np.int32)]
    out = pack_sequences(toks, 12)
    assert out["tokens"][:5].tolist() == [1, 2, 3, 4, 5]
    assert out["segment_ids"][:8].tolist() == [0] * 5 + [1] * 3
    assert out["segment_ids"][8:].tolist() == [-1] * 4  # padding
    # next-token targets within segments; boundaries masked
    assert out["targets"][:4].tolist() == [2, 3, 4, 5]
    assert out["loss_mask"][4] == 0.0  # last token of segment 0
    assert out["loss_mask"][7] == 0.0  # last token of segment 1
    assert out["positions"][5] == 0  # restart per segment


# ===========================================================================
# checkpoint roundtrip
# ===========================================================================
def test_checkpoint_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.io import latest_step

    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored = load_checkpoint(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ===========================================================================
# HLO analyzer
# ===========================================================================
def test_hlo_analyzer_counts_loops():
    import jax
    import jax.numpy as jnp

    from repro.launch import hlo as H

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    cost = H.analyze_hlo_text(jax.jit(f).lower(w, x).compile().as_text())
    # 10 iterations x 2*8*64*64 matmul flops — the loop must be multiplied
    assert cost.flops >= 10 * 2 * 8 * 64 * 64


def test_hlo_analyzer_replica_groups():
    from repro.launch.hlo import _parse_groups, _parse_pairs

    g = _parse_groups("replica_groups=[2,4]<=[8], dims={1}")
    assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]
    g = _parse_groups("replica_groups={{0,2},{1,3}}, foo")
    assert g == [[0, 2], [1, 3]]
    p = _parse_pairs("source_target_pairs={{0,1},{1,0}}")
    assert p == [(0, 1), (1, 0)]


# ===========================================================================
# end-to-end drivers (smoke)
# ===========================================================================
def test_train_driver_end_to_end(capsys):
    from repro.launch import train as train_mod

    rc = train_mod.main([
        "--arch", "qwen-1.5b", "--reduced", "--steps", "3",
        "--strategy", "lb_mini", "--schedule", "minibatch", "--comm", "odc",
        "--minibatch-per-device", "2", "--max-tokens", "128",
        "--max-len", "96",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "done" in out and "loss=" in out


def test_serve_driver_end_to_end(capsys):
    from repro.launch import serve as serve_mod

    rc = serve_mod.main([
        "--arch", "mamba2-2.7b", "--reduced", "--batch", "4",
        "--prompt-len", "32", "--gen", "4",
    ])
    assert rc == 0
    assert "decoded" in capsys.readouterr().out


# ===========================================================================
# multi-minibatch / bounded-staleness simulation (paper §6.2)
# ===========================================================================
def test_simulate_training_staleness_monotone():
    from repro.sim import simulate_training

    steps = []
    for t in range(12):
        lens = sample_lengths("longalign", 32, seed=t).tolist()
        lens = [min(l, 65_536) for l in lens]
        steps.append((STRATEGIES["lb_mini"](lens, 8, 65_536), lens))
    speed = [1.0] * 8
    speed[0] = 0.5
    t_coll = simulate_training(steps, scheme="collective",
                               device_speed=speed)
    t_sync = simulate_training(steps, scheme="odc", device_speed=speed)
    t_ssp2 = simulate_training(steps, scheme="odc", staleness=2,
                               device_speed=speed)
    t_ssp4 = simulate_training(steps, scheme="odc", staleness=4,
                               device_speed=speed)
    assert t_sync <= t_coll + 1e-9
    assert t_ssp2 <= t_sync + 1e-9
    assert t_ssp4 <= t_ssp2 + 1e-9
    # staleness never beats the straggler's own busy-time lower bound
    lb = sum(
        sum(sum(lens[i] for i in mb) for mb in plan.assignments[0])
        for plan, lens in steps) * 0  # structural lower bound placeholder
    assert t_ssp4 > 0
