"""Timeline-core tests: the event engine must be float-identical to the
retired closed forms, conserve busy time, explain every idle second, and
round-trip through the Chrome-trace schema.

The closed forms the simulator used before the timeline refactor are
copied here verbatim as reference implementations — the parity properties
assert bit-equality (`==`, not allclose) between the event engine and
that arithmetic on random plans / schemes / profiles / staleness, which
is the contract that keeps the four BENCH_*.json baselines byte-stable.
"""
import json
import math
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest

try:  # only the @given tests need hypothesis; the rest run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.balance import DeviceProfile, STRATEGIES, make_straggler_profile
from repro.sim import (
    CommModel,
    GenModel,
    SimConfig,
    Timeline,
    get_policy,
    simulate_minibatch,
    simulate_posttrain,
    simulate_training,
)
from repro.sim.engine import _scheme_backend, _step_times_and_wire
from repro.sim.timeline import BUSY_KINDS, EVENT_KINDS
from repro.sim.trace import chrome_trace, read_trace, write_trace

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property tests need the 'test' extra: pip install -e .[test]")
SCHEMES = ("collective", "odc", "overlap", "hier")


# ===========================================================================
# reference: the retired closed forms, verbatim
# ===========================================================================
def _ref_minibatch(times, cl, L, discipline):
    """sim/engine.py's pre-timeline arithmetic for one minibatch."""
    busy = [sum(ts) for ts in times]
    if discipline == "pipelined":
        finish = []
        for d, (b, ts) in enumerate(zip(busy, times)):
            t = cl[d] if ts else 0.0
            for mb_t in ts:
                t += L * max(mb_t / L, cl[d])
            finish.append(min(t, b + L * cl[d] * len(ts)))
        makespan = max(finish) if finish else 0.0
    elif discipline == "independent":
        finish = [b + L * cl[d] * len(ts)
                  for d, (b, ts) in enumerate(zip(busy, times))]
        makespan = max(finish) if finish else 0.0
    else:  # lockstep
        D = len(times)
        M = max((len(ts) for ts in times), default=0)
        comm_gate = max(cl) if cl else 0.0
        makespan = 0.0
        for m in range(M):
            per_layer = [
                (times[d][m] / L if m < len(times[d]) else 0.0)
                for d in range(D)
            ]
            makespan += L * (max(per_layer) + comm_gate)
        finish = [makespan] * D
    return makespan, finish, busy


def _ref_staleness(steps, scheme, cfg, K, profile=None):
    """The unified bounded-staleness recurrence: per-step device durations
    from the single minibatch arithmetic, SSP gates between steps."""
    backend = _scheme_backend(scheme)
    T, D = len(steps), steps[0][0].world_size
    durs = []
    for t, (plan, lens) in enumerate(steps):
        times, cl = _step_times_and_wire(plan, lens, cfg, backend, None,
                                         profile, t)
        _, finish, _ = _ref_minibatch(times, cl, cfg.num_layers,
                                      backend.discipline)
        durs.append(finish)
    f = [0.0] * D
    barrier = [0.0] * (T + 1)
    for t in range(T):
        gate = barrier[t - K + 1] if t - K + 1 >= 0 else 0.0
        f = [max(f[d], gate) + durs[t][d] for d in range(D)]
        barrier[t + 1] = max(f)
    return barrier[T]


# ===========================================================================
# strategies
# ===========================================================================
if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=40, deadline=None)

    @st.composite
    def sim_cases(draw):
        world = draw(st.integers(1, 8))
        n = draw(st.integers(world, 4 * world))
        lens = draw(st.lists(st.integers(1, 4000), min_size=n, max_size=n))
        scheme = draw(st.sampled_from(SCHEMES))
        strategy = draw(st.sampled_from(("lb_mini", "lb_micro",
                                         "local_sort")))
        cfg = SimConfig(
            num_layers=draw(st.sampled_from((1, 8, 24))),
            overlap=draw(st.sampled_from((0.0, 0.5, 1.0))),
            comm=CommModel(devices_per_node=draw(st.sampled_from((4, 8)))),
        )
        profile = None
        if draw(st.booleans()):
            profile = DeviceProfile(
                speeds=tuple(draw(st.lists(
                    st.floats(0.25, 4.0), min_size=world, max_size=world))),
                comm_scale=tuple(draw(st.lists(
                    st.floats(0.5, 4.0), min_size=world, max_size=world))),
                jitter=draw(st.sampled_from((0.0, 0.1))),
                seed=draw(st.integers(0, 3)),
            )
        plan = STRATEGIES[strategy](lens, world, max_tokens=8192)
        return plan, lens, scheme, cfg, profile
else:  # pragma: no cover - placeholders so the module imports (the @given
    #                        tests themselves are skipped via the mark)
    SETTINGS = {}

    def sim_cases():
        return None

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(**kw):
        return lambda f: f

    def settings(**kw):
        return lambda f: f


# ===========================================================================
# parity: timeline == closed forms, bit for bit
# ===========================================================================
@needs_hypothesis
@settings(**SETTINGS)
@given(case=sim_cases(), step=st.integers(0, 5))
def test_minibatch_timeline_matches_closed_form(case, step):
    plan, lens, scheme, cfg, profile = case
    r = simulate_minibatch(plan, lens, scheme=scheme, cfg=cfg,
                           profile=profile, step=step)
    backend = _scheme_backend(scheme)
    times, cl = _step_times_and_wire(plan, lens, cfg, backend, None,
                                     profile, step)
    mk, finish, busy = _ref_minibatch(times, cl, cfg.num_layers,
                                      backend.discipline)
    assert r.makespan == mk              # bit-exact, not approx
    assert r.device_finish == finish
    assert r.device_busy == busy


@needs_hypothesis
@settings(**SETTINGS)
@given(case=sim_cases(), extra=st.integers(1, 3), K=st.integers(1, 3))
def test_training_staleness_matches_unified_recurrence(case, extra, K):
    plan, lens, scheme, cfg, profile = case
    if scheme == "collective":
        scheme = "odc"  # lockstep takes the synchronous branch
    steps = [(plan, lens)] * extra
    got = simulate_training(steps, scheme=scheme, cfg=cfg, staleness=K,
                            profile=profile)
    assert got == _ref_staleness(steps, scheme, cfg, K, profile)


@needs_hypothesis
@settings(**SETTINGS)
@given(case=sim_cases())
def test_training_sync_is_sum_of_minibatch_makespans(case):
    plan, lens, scheme, cfg, profile = case
    steps = [(plan, lens)] * 3
    got = simulate_training(steps, scheme=scheme, cfg=cfg, profile=profile)
    total = 0.0
    for t in range(3):
        total += simulate_minibatch(plan, lens, scheme=scheme, cfg=cfg,
                                    profile=profile, step=t).makespan
    assert got == total


# ===========================================================================
# conservation + attribution
# ===========================================================================
@needs_hypothesis
@settings(**SETTINGS)
@given(case=sim_cases())
def test_busy_conservation_and_bubble_bounds(case):
    plan, lens, scheme, cfg, profile = case
    r = simulate_minibatch(plan, lens, scheme=scheme, cfg=cfg,
                           profile=profile)
    assert 0.0 <= r.bubble_rate <= 1.0
    # Σ compute-event durations == device_busy, bit for bit (the events
    # are laid in the same order the busy sum folds)
    for d in range(plan.world_size):
        lane = r.timeline.lane(f"dev{d}")
        ev_busy = 0.0
        for ev in lane.events:
            if ev.kind in BUSY_KINDS:
                ev_busy += ev.duration
        assert ev_busy == r.device_busy[d]
        assert lane.t <= r.makespan or math.isclose(lane.t, r.makespan)


@needs_hypothesis
@settings(**SETTINGS)
@given(case=sim_cases())
def test_idle_attribution_closes_per_device(case):
    plan, lens, scheme, cfg, profile = case
    r = simulate_minibatch(plan, lens, scheme=scheme, cfg=cfg,
                           profile=profile)
    attr = r.idle_attribution
    assert set(attr) == {f"dev{d}" for d in range(plan.world_size)}
    for d in range(plan.world_size):
        lane = attr[f"dev{d}"]
        assert lane["busy"] == r.device_busy[d]
        idle = (lane["comm"] + lane["barrier"] + lane["gate"]
                + lane["push"] + lane["drain"])
        # idle attribution sums to makespan − busy (up to fp reassociation
        # of the per-kind sums; the cursors themselves are exact)
        assert math.isclose(lane["busy"] + idle, r.makespan,
                            rel_tol=1e-9, abs_tol=1e-12)


# ===========================================================================
# Chrome-trace round-trip
# ===========================================================================
@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(case=sim_cases())
def test_chrome_trace_round_trips(case, tmp_path_factory):
    plan, lens, scheme, cfg, profile = case
    r = simulate_minibatch(plan, lens, scheme=scheme, cfg=cfg,
                           profile=profile)
    path = os.path.join(str(tmp_path_factory.mktemp("traces")), "t.json")
    write_trace(path, r.timeline)
    d = read_trace(path)
    assert d == chrome_trace(r.timeline)  # byte-faithful serialization
    evs = d["traceEvents"]
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == {ln.name for ln in r.timeline.lanes}
    last_ts = {}
    for e in evs:
        if e["ph"] != "X":
            continue
        assert e["cat"] in EVENT_KINDS
        assert e["ts"] >= 0 and e["dur"] > 0
        # per-lane timestamps are monotone non-decreasing
        assert e["ts"] >= last_ts.get(e["tid"], 0.0)
        last_ts[e["tid"]] = e["ts"]
    assert d["otherData"]["source"] == "sim"
    assert "idle_attribution" in d["otherData"]


def test_trace_is_valid_json_for_empty_timeline(tmp_path):
    path = str(tmp_path / "empty.json")
    write_trace(path, Timeline(meta={"model": "empty"}))
    with open(path) as f:
        d = json.load(f)
    assert d["traceEvents"] == []
    assert d["otherData"]["makespan_s"] == 0.0


# ===========================================================================
# policy composition (the scenarios the string ladder forbade)
# ===========================================================================
def _case(world=8, seed=0):
    from repro.data import sample_lengths
    lens = [min(int(l), 65_536)
            for l in sample_lengths("longalign", world * 4, seed)]
    return STRATEGIES["lb_mini"](lens, world, 65_536), lens


def test_policy_override_matches_registered_backend():
    """scheme='odc' + policy='pipelined' is exactly the odc-overlap
    backend: same cost model, same policy object."""
    plan, lens = _case()
    cfg = SimConfig(overlap=0.0)
    a = simulate_minibatch(plan, lens, scheme="odc", cfg=cfg,
                           policy="pipelined")
    b = simulate_minibatch(plan, lens, scheme="overlap", cfg=cfg)
    assert a.makespan == b.makespan
    assert a.device_finish == b.device_finish


def test_pipelined_hier_composes_and_dominates():
    """The composed cell: hier comm under the pipelined policy is never
    slower than plain hier (in-line fallback) nor than odc-overlap (hier
    per-layer comm lower-bounds flat ODC's)."""
    world = 16
    plan, lens = _case(world)
    cfg = SimConfig(overlap=0.0, comm=CommModel(devices_per_node=8))
    ph = simulate_minibatch(plan, lens, scheme="hier", cfg=cfg,
                            policy="pipelined")
    h = simulate_minibatch(plan, lens, scheme="hier", cfg=cfg)
    oo = simulate_minibatch(plan, lens, scheme="overlap", cfg=cfg)
    assert ph.makespan <= h.makespan * (1 + 1e-12)
    assert ph.makespan <= oo.makespan * (1 + 1e-12)
    assert ph.timeline.meta["policy"] == "pipelined"
    assert ph.timeline.meta["scheme"] == "hier"


def test_unknown_policy_rejected():
    plan, lens = _case(2)
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        simulate_minibatch(plan, lens, scheme="odc", policy="warp")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_policy("warp")


def test_backends_carry_policy_objects():
    from repro.core import backend as B
    from repro.sim.timeline import SchedulingPolicy
    for name in ("collective", "odc", "odc-overlap", "hier"):
        be = B.get_backend(name)
        assert isinstance(be.policy, SchedulingPolicy)
        assert be.discipline == be.policy.name  # legacy string view


# ===========================================================================
# posttrain composition: heterogeneous decode slots + overlapped push
# ===========================================================================
def _pt_steps(n=5, world=8):
    return [_case(world, seed=s) for s in range(n)]


def test_posttrain_unit_slot_speeds_are_noop():
    steps = _pt_steps()
    gen0 = GenModel(time_per_token=2e-5)
    gen1 = GenModel(time_per_token=2e-5, slot_speeds=(1.0,) * 8)
    a = simulate_posttrain(steps, scheme="async", staleness=1, comm="odc",
                           gen=gen0)
    b = simulate_posttrain(steps, scheme="async", staleness=1, comm="odc",
                           gen=gen1)
    assert a.makespan == b.makespan
    assert a.gen_time == b.gen_time


def test_posttrain_overlapped_push_never_slower():
    steps = _pt_steps()
    prof = make_straggler_profile("one_slow", 8, slow_factor=2.0)
    for K in (0, 1, 2):
        for slot_speeds in ((), tuple(prof.speeds)):
            block = simulate_posttrain(
                steps, scheme="async", staleness=K, comm="odc",
                gen=GenModel(time_per_token=2e-5, slot_speeds=slot_speeds))
            over = simulate_posttrain(
                steps, scheme="async", staleness=K, comm="odc",
                gen=GenModel(time_per_token=2e-5, slot_speeds=slot_speeds,
                             push_overlap=True))
            assert over.makespan <= block.makespan * (1 + 1e-12)


def test_posttrain_slow_slots_never_finish_waves_earlier():
    steps = _pt_steps()
    prof = make_straggler_profile("one_slow", 8, slow_factor=2.0)
    base = simulate_posttrain(steps, scheme="sync", comm="odc",
                              gen=GenModel(time_per_token=2e-5))
    het = simulate_posttrain(
        steps, scheme="sync", comm="odc",
        gen=GenModel(time_per_token=2e-5, slot_speeds=tuple(prof.speeds)))
    # sync waves serialize, so each slowed wave can only push later
    assert all(h >= b for h, b in zip(het.gen_time, base.gen_time))


def test_posttrain_slot_speed_length_validated():
    with pytest.raises(ValueError, match="slot_speeds"):
        simulate_posttrain(_pt_steps(2), scheme="sync", comm="odc",
                           gen=GenModel(slot_speeds=(1.0, 2.0)))


def test_posttrain_timeline_attribution_closes():
    steps = _pt_steps()
    r = simulate_posttrain(steps, scheme="async", staleness=1, comm="odc",
                           gen=GenModel(time_per_token=2e-5))
    attr = r.idle_attribution
    tr = attr["trainer"]
    busy = sum(f - s for s, f in zip(r.train_start, r.train_finish))
    assert math.isclose(tr["busy"], busy, rel_tol=1e-9, abs_tol=1e-12)
    idle = tr["comm"] + tr["barrier"] + tr["gate"] + tr["push"] + tr["drain"]
    assert math.isclose(idle, r.trainer_idle, rel_tol=1e-9, abs_tol=1e-9)
    # decode work lands on the slot lanes
    assert any(attr[f"slot{i}"]["busy"] > 0 for i in range(8))
