"""Weight-stationary expert parallelism (moe_ep='data') — the §Perf
beyond-paper optimization: expert weights stay sharded on the FSDP axis,
tokens all_to_all to them.  Must be numerically identical to the FSDP
gather baseline and must replace expert all-gathers with all-to-alls."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core.gspmd import (GSPMDConfig, ShardingRules, make_train_step,
                              moe_ep_data_axis, param_pspecs)
from repro.launch import hlo as H
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init

KEY = jax.random.PRNGKey(0)


def _setup(arch):
    # big capacity factor: no token drops, so dispatch layouts can't change
    # numerics between the baseline and EP paths
    cfg = dataclasses.replace(get_reduced(arch), moe_capacity_factor=8.0)
    from repro import compat
    mesh = (make_host_mesh(data=4, model=2)
            if compat.supports_partial_auto()
            else make_host_mesh(data=8, model=1))
    params = T.init_params(cfg, KEY)
    M, Bm, S = 2, 8, 32
    kb = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "positions": jnp.tile(jnp.arange(S)[None, None], (M, Bm, 1)),
        "segment_ids": jnp.zeros((M, Bm, S), jnp.int32),
        "targets": jax.random.randint(kb, (M, Bm, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((M, Bm, S), jnp.float32),
    }
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        batch["vision_embeds"] = jax.random.normal(
            kb, (M, Bm, cfg.frontend_tokens, cfg.d_model))
    return cfg, mesh, params, batch


def _run(cfg, mesh, params, batch, moe_ep, schedule="layer"):
    gcfg = GSPMDConfig(rules=ShardingRules(), schedule=schedule,
                       comm="collective", moe_ep=moe_ep, block_kv=64)
    step = make_train_step(cfg, mesh, gcfg, AdamWConfig(lr=1e-2))
    with mesh:
        jstep = jax.jit(step)
        newp, _, metrics = jstep(params, adamw_init(params), batch)
        hlo = jstep.lower(params, adamw_init(params), batch).compile().as_text()
    return newp, float(metrics["loss"]), H.analyze_hlo_text(hlo)


# tier-1 keeps one (schedule, arch) cell; the rest run in the CI full job
@pytest.mark.parametrize("schedule,arch", [
    ("layer", "grok-1-314b"),
    pytest.param("minibatch", "grok-1-314b", marks=pytest.mark.slow),
    pytest.param("layer", "llama4-maverick-400b-a17b",
                 marks=pytest.mark.slow),
    pytest.param("minibatch", "llama4-maverick-400b-a17b",
                 marks=pytest.mark.slow),
])
def test_ep_data_matches_baseline(arch, schedule):
    cfg, mesh, params, batch = _setup(arch)
    p0, l0, _ = _run(cfg, mesh, params, batch, "none", schedule)
    p1, l1, c1 = _run(cfg, mesh, params, batch, "data", schedule)
    assert abs(l0 - l1) < 1e-5
    dp = max(float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
    assert dp < 2e-3, dp
    # EP dispatch must appear in the HLO
    assert c1.coll_count["all-to-all"] > 0


def test_ep_data_axis_resolution():
    """E=4 divides data=4 on the host mesh; production grok (E=8, data=16)
    must fall back to None."""
    mesh = make_host_mesh(data=4, model=2)
    cfg = get_reduced("llama4-maverick-400b-a17b")  # reduced E=4
    assert moe_ep_data_axis(cfg, ShardingRules(), mesh, "data") == "data"
    assert moe_ep_data_axis(cfg, ShardingRules(), mesh, "none") is None
    big = get_reduced("llama4-maverick-400b-a17b", num_experts=6)
    assert moe_ep_data_axis(big, ShardingRules(), mesh, "data") is None


def test_ep_specs_keep_experts_sharded_on_data():
    mesh = make_host_mesh(data=4, model=2)
    cfg = get_reduced("llama4-maverick-400b-a17b")
    params = jax.eval_shape(lambda k: T.init_params(cfg, k), KEY)
    specs = param_pspecs(cfg, params, ShardingRules(), mesh, moe_ep="data")
    flat = {"/".join(str(k.key) for k in p if hasattr(k, "key")): s
            for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]}
    s = flat["layers/moe/moe/w_up"]
    assert s[1] == "data"  # stacked: (layer, E, d, f) -> E over data
