"""Property-based tests (hypothesis) on the system's invariants."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'test' extra: pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.balance import STRATEGIES, karmarkar_karp
from repro.balance.cost import CostModel, get_compute_costs
from repro.balance.kk import partition_sums
from repro.data import pack_sequences
from repro.sim import simulate_minibatch

SETTINGS = dict(max_examples=40, deadline=None)


# ===========================================================================
# Karmarkar–Karp invariants
# ===========================================================================
@settings(**SETTINGS)
@given(
    costs=st.lists(st.floats(0.1, 1e4), min_size=1, max_size=40),
    k=st.integers(1, 8),
)
def test_kk_partition_is_exact_cover(costs, k):
    parts = karmarkar_karp(costs, k)
    assert len(parts) == k
    seen = sorted(i for p in parts for i in p)
    assert seen == list(range(len(costs)))
    # KK max-sum never exceeds (sum + max): trivial upper bound sanity
    sums = partition_sums(costs, parts)
    assert max(sums) <= sum(costs) + 1e-6
    # and is at least the lower bound max(mean, biggest item)
    assert max(sums) >= max(sum(costs) / k, max(costs)) - 1e-6


@settings(**SETTINGS)
@given(
    costs=st.lists(st.floats(0.5, 100), min_size=8, max_size=32),
)
def test_kk_equal_size_counts(costs):
    k = 4
    n = (len(costs) // k) * k
    parts = karmarkar_karp(costs[:n], k, equal_size=True)
    counts = sorted(len(p) for p in parts)
    assert counts[-1] - counts[0] <= 1


# ===========================================================================
# balance-strategy invariants
# ===========================================================================
@settings(**SETTINGS)
@given(
    lens=st.lists(st.integers(16, 8192), min_size=8, max_size=48),
    world=st.sampled_from([2, 4, 8]),
    strategy=st.sampled_from(list(STRATEGIES)),
)
def test_plans_cover_and_respect_memory(lens, world, strategy):
    max_tokens = 8192
    plan = STRATEGIES[strategy](lens, world, max_tokens)
    plan.validate(len(lens))
    for dev in plan.assignments:
        for mb in dev:
            assert sum(lens[i] for i in mb) <= max_tokens
    if strategy not in ("lb_mini", "lb_mini_het"):
        assert plan.uniform_microbatches()


# ===========================================================================
# simulator invariants: Eq. 1 dominance
# ===========================================================================
@settings(**SETTINGS)
@given(
    lens=st.lists(st.integers(64, 16384), min_size=8, max_size=32),
    strategy=st.sampled_from(list(STRATEGIES)),
)
def test_odc_makespan_never_exceeds_collective(lens, strategy):
    """max_d Σ_m t ≤ Σ_m max_d t — ODC's relaxation can only help."""
    plan = STRATEGIES[strategy](lens, 4, 16_384)
    t_c = simulate_minibatch(plan, lens, scheme="collective").makespan
    t_o = simulate_minibatch(plan, lens, scheme="odc").makespan
    assert t_o <= t_c + 1e-9
    # and both are at least the busiest device's work
    busy = simulate_minibatch(plan, lens, scheme="odc").device_busy
    assert t_o >= max(busy) - 1e-6


# ===========================================================================
# packing invariants
# ===========================================================================
@settings(**SETTINGS)
@given(
    sizes=st.lists(st.integers(1, 64), min_size=0, max_size=6),
)
def test_packing_roundtrip(sizes):
    buffer_len = max(sum(sizes), 1)
    rng = np.random.RandomState(0)
    toks = [rng.randint(1, 1000, size=s).astype(np.int32) for s in sizes]
    out = pack_sequences(toks, buffer_len)
    # every real token present, in order, with per-segment positions
    cur = 0
    for seg, t in enumerate(toks):
        got = out["tokens"][cur: cur + len(t)]
        np.testing.assert_array_equal(got, t)
        np.testing.assert_array_equal(
            out["positions"][cur: cur + len(t)], np.arange(len(t)))
        assert (out["segment_ids"][cur: cur + len(t)] == seg).all()
        cur += len(t)
    # loss mask is zero on padding and on each segment's last token
    assert out["loss_mask"][cur:].sum() == 0
    assert float(out["loss_mask"].sum()) == sum(max(s - 1, 0) for s in sizes)


# ===========================================================================
# cost-model invariants
# ===========================================================================
@settings(**SETTINGS)
@given(s=st.integers(1, 100_000))
def test_cost_model_monotone_and_superlinear(s):
    cm = CostModel()
    assert cm.sample_cost(s + 1) > cm.sample_cost(s)
    # quadratic: cost(2s) > 2*cost(s) for full attention
    assert cm.sample_cost(2 * s) > 2 * cm.sample_cost(s) - 1e-6
    # attention-free is exactly linear
    lin = CostModel(attention_free=True)
    assert abs(lin.sample_cost(2 * s) - 2 * lin.sample_cost(s)) < 1e-6


@settings(**SETTINGS)
@given(s=st.integers(1024, 100_000))
def test_cost_model_window_caps_quadratic(s):
    full = CostModel()
    win = CostModel(window=1024)
    assert win.sample_cost(s) <= full.sample_cost(s) + 1e-6
